"""Tensor-parallel sharded serving (``ServeEngine(mesh=...)``).

Three layers of checks, mirroring the exactness argument in
docs/distributed.md:

* host-side algebra — plane-prefix truncation commutes with column
  sharding (all even bits x signedness x packed layouts), and the
  bit-serial wire pack/unpack is lossless and commutes with a tiled
  gather;
* spec rules — ``serve_tp_param_spec`` / ``serve_tp_cache_spec`` shard
  exactly the serve-TP projections and raise (never silently drop) on
  non-dividing axes;
* fake-device end-to-end — a 2-device mesh engine is token-identical to
  the unsharded engine across mixed 8/4/2 batches and a mid-stream
  ``set_tier`` migration, and the compiled decode step's all-gathers move
  int8 / bit-packed uint8 codes, not floats.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import sharding_rules, tp_serve
from repro.kernels import ops

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(body: str, devices: int = 2) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


# ------------------------------------------------------ wire format (host)
@pytest.mark.parametrize("bits", [2, 4])
def test_wire_pack_roundtrip(bits):
    rng = np.random.default_rng(0)
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    q = jnp.asarray(rng.integers(lo, hi + 1, size=(3, 64)).astype(np.int8))
    p = tp_serve.wire_pack(q, bits)
    assert p.dtype == jnp.uint8
    assert p.shape == (3, 64 * bits // 8)
    assert np.array_equal(np.asarray(tp_serve.wire_unpack(p, bits)),
                          np.asarray(q))


@pytest.mark.parametrize("bits", [2, 4])
def test_wire_pack_commutes_with_tiled_gather(bits):
    """unpack(concat(pack(shard_i))) == concat(shard_i): packing is
    per-shard-contiguous, so a tiled all-gather of packed bytes decodes to
    the gather of the codes."""
    rng = np.random.default_rng(1)
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    shards = [jnp.asarray(rng.integers(lo, hi + 1, size=(2, 32))
                          .astype(np.int8)) for _ in range(4)]
    gathered_packed = jnp.concatenate(
        [tp_serve.wire_pack(s, bits) for s in shards], axis=-1)
    assert np.array_equal(
        np.asarray(tp_serve.wire_unpack(gathered_packed, bits)),
        np.asarray(jnp.concatenate(shards, axis=-1)))


def test_wire_bytes_per_element():
    assert tp_serve.wire_bytes_per_element(8) == 1.0
    assert tp_serve.wire_bytes_per_element(6) == 1.0
    assert tp_serve.wire_bytes_per_element(4) == 0.5
    assert tp_serve.wire_bytes_per_element(2) == 0.25
    assert tp_serve.wire_bytes_per_element(4, signed=False) == 1.0


# ------------------------------------- truncation commutes with sharding
@pytest.mark.parametrize("signed", [True, False])
@pytest.mark.parametrize("eff_bits", [2, 4, 6, 8])
@pytest.mark.parametrize("packed", [False, True])
def test_truncate_commutes_with_shard(eff_bits, signed, packed):
    """Plane-prefix truncation then column-sharding == sharding then
    truncation, bitwise — superplane codes and scales are per-COLUMN, so
    every tier mechanism works unchanged on an N-shard."""
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    qw = ops.prepare_superplane(w, signed=signed, packed=packed)
    trunc_full = ops.truncate_weight(qw, eff_bits)
    for n in (2, 4):
        for i in range(n):
            def col(a):
                step = a.shape[-1] // n
                return a[..., i * step:(i + 1) * step]

            shard = dataclasses.replace(
                qw,
                planes=None if packed else col(qw.planes),
                packed=col(qw.packed) if packed else None,
                scale=col(qw.scale))
            a = ops.truncate_weight(shard, eff_bits)   # shard -> truncate
            assert np.array_equal(np.asarray(a.scale),
                                  np.asarray(col(trunc_full.scale)))
            if packed:
                assert np.array_equal(np.asarray(a.packed),
                                      np.asarray(col(trunc_full.packed)))
            else:
                assert np.array_equal(np.asarray(a.planes),
                                      np.asarray(col(trunc_full.planes)))
            assert a.w_bits == trunc_full.w_bits


# ----------------------------------------------------------- spec rules
def test_serve_tp_param_spec_targets_and_raises():
    planes = jnp.zeros((4, 32, 16), jnp.int8)
    q_path = "['periods']['pos0']['attn']['q_proj']['w'].planes"
    spec = sharding_rules.serve_tp_param_spec(q_path, planes, n=2,
                                              kv_shards=True)
    assert spec == P(None, None, "model")
    # k/v shard only under kv_shards.
    k_path = "['periods']['pos0']['attn']['k_proj']['w'].scale"
    scale = jnp.zeros((1, 16), jnp.float32)
    assert sharding_rules.serve_tp_param_spec(
        k_path, scale, n=2, kv_shards=True) == P(None, "model")
    assert sharding_rules.serve_tp_param_spec(
        k_path, scale, n=2, kv_shards=False) == P()
    # Norms / embeddings / non-QW leaves: replicated.
    assert sharding_rules.serve_tp_param_spec(
        "['final_norm']['scale']", jnp.zeros((16,)), n=2,
        kv_shards=True) == P()
    # Exact-or-error: a non-dividing last axis raises.
    with pytest.raises(ValueError, match="does not divide"):
        sharding_rules.serve_tp_param_spec(
            q_path, jnp.zeros((4, 32, 15), jnp.int8), n=2, kv_shards=True)


def test_serve_tp_cache_spec_targets_and_raises():
    k = jnp.zeros((1, 2, 8, 4, 16), jnp.bfloat16)   # [periods,B,S,KVH,Dh]
    spec = sharding_rules.serve_tp_cache_spec(".k", k, n=2, kv_shards=True)
    assert spec == P(None, None, None, "model", None)
    assert sharding_rules.serve_tp_cache_spec(
        ".k", k, n=2, kv_shards=False) == P()
    assert sharding_rules.serve_tp_cache_spec(
        ".length", jnp.zeros((1, 2), jnp.int32), n=2, kv_shards=True) == P()
    with pytest.raises(ValueError, match="does not divide"):
        sharding_rules.serve_tp_cache_spec(
            ".v", jnp.zeros((1, 2, 8, 3, 16)), n=2, kv_shards=True)


def test_tpconfig_gathers_only_o_and_down():
    tp = tp_serve.TPConfig(n=2)
    assert tp.gathers("layers.pos0.attn.o_proj")
    assert tp.gathers("layers.pos1.mlp.down_proj")
    assert not tp.gathers("layers.pos0.attn.q_proj")
    assert not tp.gathers("layers.pos0.mlp.up_proj")
    assert not tp.gathers("layers.pos0.moe.down_proj")   # MoE is replicated
    assert not tp.gathers("lm_head")


def test_engine_rejects_mesh_without_model_axis():
    from repro.configs import reduced_config
    from repro.core.policy import uniform_schedule
    from repro.models.layers import Runtime
    from repro.models.transformer import LM
    from repro.serve import ServeEngine
    cfg = reduced_config("qwen3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = uniform_schedule({"8/8": (8, 8)})
    rt = Runtime(policy=sched.policy_for(), mode="serve", schedule=sched)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape((1,)), ("data",))
    with pytest.raises(ValueError, match="'model' axis"):
        ServeEngine(model, params, rt, max_batch=2, max_len=32, mesh=mesh)


# -------------------------------------------------- wire-cost accounting
def test_decode_wire_stats_ratios():
    from repro.configs import reduced_config
    cfg = reduced_config("qwen3-8b")       # attn+mlp every layer
    tp = tp_serve.TPConfig(n=2)
    s8 = tp_serve.decode_wire_stats(cfg, tp, ((4, 8),))
    assert s8["bytes_per_element"] == 1.0
    assert s8["vs_f32"] == 4.0
    s4 = tp_serve.decode_wire_stats(cfg, tp, ((4, 4),))
    assert s4["bytes_per_element"] == 0.5
    assert s4["vs_f32"] == 8.0
    s2 = tp_serve.decode_wire_stats(cfg, tp, ((4, 2),))
    assert s2["vs_f32"] == 16.0
    mixed = tp_serve.decode_wire_stats(cfg, tp, ((2, 8), (1, 4), (1, 2)))
    assert s4["vs_f32"] > mixed["vs_f32"] > s8["vs_f32"]
    # Ring term: each device sends its 1/n shard to n-1 peers.
    tp4 = tp_serve.TPConfig(n=4)
    s8_4 = tp_serve.decode_wire_stats(cfg, tp4, ((4, 8),))
    assert s8_4["quant_gather_bytes"] / s8["quant_gather_bytes"] \
        == pytest.approx((3 / 4) / (1 / 2))


# -------------------------------------------------- fake-device end-to-end
def test_sharded_engine_token_identity_with_migration():
    """2-device mesh engine == unsharded engine, token for token, across
    mixed 8/4/2 batches, per-slot KV precisions, and a mid-stream
    ``set_tier`` KV migration; KV heads genuinely shard."""
    out = run_subprocess("""
        import dataclasses
        import jax, numpy as np
        from repro.configs import reduced_config
        from repro.core.policy import uniform_schedule
        from repro.launch.mesh import make_serve_mesh
        from repro.models.layers import Runtime
        from repro.models.transformer import LM
        from repro.serve import Request, ServeEngine
        from repro.serve.handle import RequestStatus

        cfg = dataclasses.replace(reduced_config("qwen3-8b"),
                                  num_kv_heads=4)
        model = LM(cfg)
        params = model.init(jax.random.PRNGKey(0))
        sched = uniform_schedule(
            {"8/8": (8, 8), "4/4": (4, 4), "2/2": (2, 2)},
            kv_tiers={"8/8": None, "4/4": 8, "2/2": 4})
        rt = Runtime(policy=sched.policy_for(), mode="serve",
                     schedule=sched)
        tiers = ["8/8", "4/4", "2/2"]

        def serve(mesh):
            rng = np.random.default_rng(0)
            eng = ServeEngine(model, params, rt, max_batch=4, max_len=64,
                              decode_chunk=4, mesh=mesh)
            reqs = [Request(uid=i,
                            prompt=rng.integers(0, cfg.vocab_size, size=4),
                            max_new_tokens=10, tier=tiers[i % 3])
                    for i in range(5)]
            handles = [eng.submit(r) for r in reqs]
            migrated = False
            while eng.has_work:
                eng.step()
                if not migrated:
                    for h in handles:
                        if (h.status is RequestStatus.RUNNING
                                and len(h.tokens) >= 2):
                            h.set_tier("2/2" if h.tier != "2/2"
                                       else "8/8")
                            migrated = True
                            break
            assert migrated
            return {h.uid: h.tokens for h in handles}, eng

        ref, _ = serve(None)
        tp2, eng2 = serve(make_serve_mesh(2))
        assert eng2._tp is not None and eng2._tp.kv_shards
        assert eng2.stats.kv_migrations == 1
        assert ref == tp2, (ref, tp2)
        print("TP_SERVE_OK", sum(len(v) for v in ref.values()))
    """)
    assert "TP_SERVE_OK" in out


def test_sharded_decode_hlo_gathers_are_quantized():
    """The compiled mixed-tier sharded decode must all-gather int8 codes
    (8-bit rows) and bit-packed uint8 bytes (4/2-bit rows).  Activations
    never ride the wire in float: the only float gathers allowed are the
    OUTPUT-column gathers (which keep the residual dtype — f32 on the CPU
    reference model — to preserve bit-identity), identified by their
    source line in tp_serve."""
    out = run_subprocess("""
        import dataclasses, inspect, re
        import jax, jax.numpy as jnp, numpy as np
        import repro.distributed.tp_serve as tps
        from repro.configs import reduced_config
        from repro.core.policy import uniform_schedule
        from repro.launch.mesh import make_serve_mesh
        from repro.models.layers import Runtime
        from repro.models.transformer import LM
        from repro.serve import ServeEngine

        cfg = dataclasses.replace(reduced_config("qwen3-8b"),
                                  num_kv_heads=4)
        model = LM(cfg)
        params = model.init(jax.random.PRNGKey(0))
        sched = uniform_schedule(
            {"8/8": (8, 8), "4/4": (4, 4), "2/2": (2, 2)})
        rt = Runtime(policy=sched.policy_for(), mode="serve",
                     schedule=sched)
        eng = ServeEngine(model, params, rt, max_batch=4, max_len=64,
                          decode_chunk=4, mesh=make_serve_mesh(2))
        groups = (("8/8", 2), ("4/4", 1), ("2/2", 1))
        perm = jnp.arange(4, dtype=jnp.int32)
        txt = eng._decode_chunk.lower(
            eng.params, eng.arena.caches, jnp.zeros((4,), jnp.int32),
            jnp.ones((4,), jnp.int32), perm, n_steps=1, tier=None,
            groups=groups).compile().as_text()
        ags = [l for l in txt.splitlines() if "all-gather(" in l]
        assert any(re.search(r"= s8\\[[0-9,]+\\]\\S* all-gather\\(", l)
                   for l in ags), ags      # int8 wire (8-bit rows)
        assert any(re.search(r"= u8\\[[0-9,]+\\]\\S* all-gather\\(", l)
                   for l in ags), ags      # bit-packed wire (4/2-bit rows)
        # Output-column gathers (the residual dtype) are the only float
        # gathers allowed; locate their call sites from the source.
        src, start = inspect.getsourcelines(tps)   # modules report start=0
        out_lines = {max(start, 1) + i for i, l in enumerate(src)
                     if "all_gather(y_loc" in l}
        assert out_lines
        for l in ags:
            if re.search(r"= (f32|bf16|f16)\\[", l):
                m = re.search(r"source_line=(\\d+)", l)
                assert m and int(m.group(1)) in out_lines, l
        print("TP_HLO_OK", len(ags))
    """)
    assert "TP_HLO_OK" in out
