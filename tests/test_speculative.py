"""Self-speculative decoding (repro.spec.speculate + engine integration).

The headline invariant: a GREEDY speculative request's token stream is
IDENTICAL to the same request decoded non-speculatively at its verify
tier — for every draft tier, every draft depth k, and regardless of what
else shares the batch.  Plus: zero weight re-preparations, sane
acceptance accounting, strict verify-step savings under full acceptance,
and deterministic sampled-mode speculation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.serve.engine as engine_mod
from repro.configs import reduced_config
from repro.core.policy import uniform_schedule
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.serve import (BatchServeEngine, Request, SamplingParams,
                         ServeEngine, SpecConfig)
from repro.spec import speculate


# ----------------------------------------------------------- fixtures
def _setup(arch="granite-3-8b"):
    cfg = reduced_config(arch)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = uniform_schedule({"8/8": (8, 8), "4/4": (4, 4), "2/2": (2, 2)},
                             kv_tiers={"8/8": 8, "4/4": 8, "2/2": 8})
    rt = Runtime(policy=sched.policy_for(), mode="serve", schedule=sched)
    return cfg, model, params, rt


def _engine(model, params, rt, max_batch=3):
    return ServeEngine(model, params, rt, max_batch=max_batch, max_len=64,
                       decode_chunk=2)


def _prompts(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [list(rng.integers(0, cfg.vocab_size, size=4 + i % 3))
            for i in range(n)]


# ------------------------------------------------- pure acceptance math
def test_accept_counts_greedy_is_prefix_match():
    v = 11
    drafts = jnp.asarray([[3, 7, 2], [5, 5, 5]], jnp.int32)
    # verify point masses: row 0 agrees at positions 0,1 then diverges;
    # row 1 diverges immediately.
    vtoks = np.array([[3, 7, 9, 1], [0, 5, 5, 5]])
    vp = jnp.asarray(np.eye(v, dtype=np.float32)[vtoks])
    qp = jnp.asarray(np.eye(v, dtype=np.float32)[np.asarray(drafts)])
    keys = jnp.zeros((2, 2), jnp.uint32)
    draws = jnp.zeros((2,), jnp.int32)
    m = speculate.accept_counts(drafts, qp, vp, keys, draws)
    assert m.tolist() == [2, 0]
    corr = speculate.correction_tokens(qp, vp, m, keys, draws)
    # stop-position verify argmax: row 0 position 2 -> 9, row 1 pos 0 -> 0
    assert corr.tolist() == [9, 0]
    emit = speculate.emission_window(drafts, corr, m)
    assert emit[0, :3].tolist() == [3, 7, 9]
    assert emit[1, :1].tolist() == [0]


def test_emission_window_full_acceptance_bonus():
    drafts = jnp.asarray([[4, 6]], jnp.int32)
    corr = jnp.asarray([8], jnp.int32)
    m = jnp.asarray([2], jnp.int32)
    emit = speculate.emission_window(drafts, corr, m)
    assert emit[0].tolist() == [4, 6, 8]


def test_spec_config_validation():
    with pytest.raises(ValueError):
        SpecConfig(draft_tier="4/4", k=0).validate()
    SpecConfig(draft_tier="4/4", k=1).validate()


# --------------------------------------------------- greedy identity
@pytest.mark.parametrize("draft_tier", ["2/2", "4/4"])
@pytest.mark.parametrize("k", [1, 2, 4])
def test_greedy_speculative_token_identical(draft_tier, k):
    cfg, model, params, rt = _setup()
    prompts = _prompts(cfg, 3)
    base = _engine(model, params, rt).run(
        [Request(uid=i, prompt=p, max_new_tokens=7, tier="8/8")
         for i, p in enumerate(prompts)])
    eng = _engine(model, params, rt)
    spec = eng.run(
        [Request(uid=i, prompt=p, max_new_tokens=7, tier="8/8",
                 spec=SpecConfig(draft_tier=draft_tier, k=k))
         for i, p in enumerate(prompts)])
    assert spec == base
    st = eng.stats
    assert st.spec_rounds > 0
    assert st.spec_draft_steps == st.spec_rounds * k
    assert st.spec_verify_steps == st.spec_rounds
    assert st.spec_emitted == sum(len(v) - 1 for v in spec.values())
    assert 0 <= st.spec_accepted <= st.spec_drafted


def test_mixed_speculative_and_plain_slots():
    """One batch: a speculative slot + plain slots at other tiers.  Every
    stream matches its solo reference; the plain slots never notice."""
    cfg, model, params, rt = _setup()
    prompts = _prompts(cfg, 3)
    ref_spec = _engine(model, params, rt).run(
        [Request(uid=0, prompt=prompts[0], max_new_tokens=8, tier="8/8")])
    ref_plain = _engine(model, params, rt).run(
        [Request(uid=1, prompt=prompts[1], max_new_tokens=8, tier="4/4"),
         Request(uid=2, prompt=prompts[2], max_new_tokens=8, tier="8/8")])
    eng = _engine(model, params, rt)
    mixed = eng.run(
        [Request(uid=0, prompt=prompts[0], max_new_tokens=8, tier="8/8",
                 spec=SpecConfig(draft_tier="4/4", k=2)),
         Request(uid=1, prompt=prompts[1], max_new_tokens=8, tier="4/4"),
         Request(uid=2, prompt=prompts[2], max_new_tokens=8, tier="8/8")])
    assert mixed[0] == ref_spec[0]
    assert mixed[1] == ref_plain[1]
    assert mixed[2] == ref_plain[2]
    st = eng.stats
    assert st.decode_slot_steps + st.decode_idle_slot_steps \
        == st.decode_steps * 3


def test_speculation_prepares_no_weights():
    """Drafting is a plane-prefix read of the preloaded superplane store:
    PREPARE_CALLS must not move after engine construction."""
    cfg, model, params, rt = _setup()
    prompts = _prompts(cfg, 2)
    eng = _engine(model, params, rt, max_batch=2)
    before = engine_mod.PREPARE_CALLS
    eng.run([Request(uid=i, prompt=p, max_new_tokens=6, tier="8/8",
                     spec=SpecConfig(draft_tier="2/2", k=3))
             for i, p in enumerate(prompts)])
    assert engine_mod.PREPARE_CALLS == before


def test_full_acceptance_beats_one_verify_step_per_token():
    """draft tier == verify tier -> every draft accepted -> strictly
    fewer verify-tier decode steps than emitted tokens (the benchmark's
    headline inequality, made deterministic)."""
    cfg, model, params, rt = _setup()
    prompts = _prompts(cfg, 2)
    eng = _engine(model, params, rt, max_batch=2)
    base = _engine(model, params, rt, max_batch=2).run(
        [Request(uid=i, prompt=p, max_new_tokens=9, tier="8/8")
         for i, p in enumerate(prompts)])
    spec = eng.run([Request(uid=i, prompt=p, max_new_tokens=9, tier="8/8",
                            spec=SpecConfig(draft_tier="8/8", k=4))
                    for i, p in enumerate(prompts)])
    assert spec == base
    st = eng.stats
    assert st.spec_verify_steps < st.spec_emitted
    # full acceptance except where the budget truncates the window
    assert st.spec_accepted > 0


def test_sampled_speculation_deterministic():
    """Sampled-mode speculation re-runs bit-identically (the stream is a
    pure function of the request seed + draw counters)."""
    cfg, model, params, rt = _setup()
    prompts = _prompts(cfg, 2)

    def serve():
        eng = _engine(model, params, rt, max_batch=2)
        out = eng.run(
            [Request(uid=i, prompt=p, max_new_tokens=6, tier="8/8",
                     sampling=SamplingParams(temperature=0.9, top_k=20,
                                             seed=7 + i),
                     spec=SpecConfig(draft_tier="4/4", k=2))
             for i, p in enumerate(prompts)])
        return out, eng.stats

    a, st_a = serve()
    b, st_b = serve()
    assert a == b
    assert st_a.spec_accepted == st_b.spec_accepted
    assert all(len(v) == 6 for v in a.values())


def test_greedy_speculative_hybrid_arch():
    """The verify window's rollback must hold for SSM caches too: the
    hybrid Mamba+attention+MoE config serves token-identically."""
    cfg, model, params, rt = _setup("jamba-1.5-large-398b")
    prompts = _prompts(cfg, 2)
    base = _engine(model, params, rt, max_batch=2).run(
        [Request(uid=i, prompt=p, max_new_tokens=6, tier="8/8")
         for i, p in enumerate(prompts)])
    spec = _engine(model, params, rt, max_batch=2).run(
        [Request(uid=i, prompt=p, max_new_tokens=6, tier="8/8",
                 spec=SpecConfig(draft_tier="4/4", k=2))
         for i, p in enumerate(prompts)])
    assert spec == base


# ------------------------------------------------------- clean errors
def test_spec_submit_validation():
    cfg, model, params, rt = _setup()
    eng = _engine(model, params, rt, max_batch=2)
    with pytest.raises(ValueError, match="unknown draft tier"):
        eng.submit(Request(uid=0, prompt=[1, 2], max_new_tokens=2,
                           tier="8/8",
                           spec=SpecConfig(draft_tier="3/3", k=2)))
    with pytest.raises(ValueError, match="k must be >= 1"):
        eng.submit(Request(uid=1, prompt=[1, 2], max_new_tokens=2,
                           tier="8/8",
                           spec=SpecConfig(draft_tier="4/4", k=0)))


def test_spec_rejected_without_schedule():
    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.core.policy import uniform_policy
    rt = Runtime(policy=uniform_policy(8, 8), mode="serve")
    eng = ServeEngine(model, params, rt, max_batch=2, max_len=32,
                      decode_chunk=2)
    with pytest.raises(ValueError, match="PrecisionSchedule"):
        eng.submit(Request(uid=0, prompt=[1, 2], max_new_tokens=2,
                           spec=SpecConfig(draft_tier="4/4", k=2)))


def test_batch_engine_rejects_spec_and_sampling():
    cfg, model, params, rt = _setup()
    eng = BatchServeEngine(model, params, rt, max_batch=2, max_len=32,
                           tier="8/8")
    with pytest.raises(ValueError, match="speculative"):
        eng.submit(Request(uid=0, prompt=[1, 2], max_new_tokens=2,
                           spec=SpecConfig(draft_tier="4/4", k=2)))
    with pytest.raises(ValueError, match="temperature"):
        eng.submit(Request(uid=1, prompt=[1, 2], max_new_tokens=2,
                           sampling=SamplingParams(temperature=0.5)))
    # temperature-0 SamplingParams are greedy: accepted
    h = eng.submit(Request(uid=2, prompt=[1, 2], max_new_tokens=2,
                           sampling=SamplingParams(temperature=0.0)))
    assert h.uid == 2


def test_spec_token_events_flagged():
    cfg, model, params, rt = _setup()
    eng = _engine(model, params, rt, max_batch=1)
    h = eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=5,
                           tier="8/8",
                           spec=SpecConfig(draft_tier="4/4", k=2)))
    eng.drain()
    # first token comes from prefill (not speculative); later tokens from
    # verify windows carry the speculative flag and the VERIFY tier.
    assert not h.events[0].speculative
    assert all(ev.speculative for ev in h.events[1:])
    assert all(ev.tier == "8/8" for ev in h.events)
    assert not any(ev.sampled for ev in h.events)
