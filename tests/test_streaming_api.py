"""The streaming serving API: request handles, submit/step/drain, SLO-aware
admission, and mid-stream tier migration.

Covers the redesign's contracts:

* ``run`` is a thin wrapper over the incremental core (token-identical to
  manual submit/step/drain; both engines implement the ``Engine``
  protocol);
* handles stream tokens (iterator + callback) and walk QUEUED -> RUNNING ->
  FINISHED;
* scheduler edge cases the policy layer must preserve: admission into a
  slot freed mid-chunk, duplicate-uid submission, zero-budget requests,
  empty-queue ``step()`` as a no-op;
* ``SLOPolicy`` admits by deadline slack priced with the hwmodel's
  per-tier cost, beating FIFO for a deadline-skewed trace;
* mid-stream ``set_tier``: the migrated KV lane is bit-identical to
  quantizing the slot's dequantized cache directly at the target
  precision, and subsequent tokens are token-identical to a fresh engine
  resumed from the migrated state at the new tier.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.policy import uniform_policy, uniform_schedule
from repro.models.layers import KVCache, Runtime
from repro.models.transformer import LM
from repro.serve import (BatchServeEngine, Engine, FIFOPolicy, Request,
                         RequestHandle, RequestStatus, Scheduler, ServeEngine,
                         SLOPolicy)
from repro.serve import slots as slots_lib
from repro.serve.scheduler import SlotState

RT_DENSE = Runtime(policy=uniform_policy(8, 8, backend="dense"),
                   mode="serve", moe_dropless=True)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def tiered(setup):
    """A two-tier schedule with maximally different KV precisions
    (bf16 vs int4-packed) — the hardest migration pair."""
    cfg, model, params = setup
    sched = uniform_schedule({"8/8": (8, 8), "2/2": (2, 2)},
                             kv_tiers={"8/8": None, "2/2": 4})
    rt = Runtime(policy=sched.policy_for(), mode="serve", moe_dropless=True,
                 schedule=sched)
    return cfg, model, params, sched, rt


def _requests(cfg, n, *, seed=0, plen=lambda i: 3 + i % 5,
              budget=lambda i: 2 + 3 * (i % 3), tier=lambda i: None,
              deadline=lambda i: None):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=plen(i)),
                    max_new_tokens=budget(i), tier=tier(i),
                    deadline=deadline(i)) for i in range(n)]


# ------------------------------------------------------------ engine protocol
def test_both_engines_satisfy_engine_protocol(setup):
    cfg, model, params = setup
    eng = ServeEngine(model, params, RT_DENSE, max_batch=2, max_len=32)
    base = BatchServeEngine(model, params, RT_DENSE, max_batch=2, max_len=32)
    assert isinstance(eng, Engine)
    assert isinstance(base, Engine)


def test_run_equals_manual_submit_step_drain(setup):
    """The compatibility wrapper: ``run`` == submit all + drain, token for
    token, on both engines."""
    cfg, model, params = setup
    reqs = _requests(cfg, 5, seed=1)
    for cls in (ServeEngine, BatchServeEngine):
        a = cls(model, params, RT_DENSE, max_batch=2, max_len=64)
        want = a.run(reqs)
        b = cls(model, params, RT_DENSE, max_batch=2, max_len=64)
        handles = [b.submit(r) for r in reqs]
        finished = b.drain()
        assert finished == want
        for h, r in zip(handles, reqs):
            assert h.done and h.tokens == want[r.uid]


# -------------------------------------------------------------------- handles
def test_handle_iterator_streams_tokens(setup):
    """``for tok in handle`` drives the engine and yields the same tokens
    the blocking API returns."""
    cfg, model, params = setup
    reqs = _requests(cfg, 3, seed=2)
    ref = ServeEngine(model, params, RT_DENSE, max_batch=2, max_len=64)
    want = ref.run(reqs)
    eng = ServeEngine(model, params, RT_DENSE, max_batch=2, max_len=64)
    handles = [eng.submit(r) for r in reqs]
    assert all(h.status is RequestStatus.QUEUED for h in handles)
    streamed = {r.uid: list(h) for h, r in zip(handles, reqs)}
    assert streamed == want
    assert all(h.done for h in handles)


def test_handle_callback_and_replay(setup):
    """Callbacks fire per token; late registration replays the buffered
    prefix so every subscriber sees the identical stream."""
    cfg, model, params = setup
    req = _requests(cfg, 1, seed=3, budget=lambda i: 6)[0]
    eng = ServeEngine(model, params, RT_DENSE, max_batch=1, max_len=64,
                      decode_chunk=2)
    h = eng.submit(req)
    live = []
    h.on_token(lambda ev: live.append((ev.index, ev.token, ev.final)))
    eng.step()                              # partial progress
    late = []
    h.on_token(lambda ev: late.append((ev.index, ev.token, ev.final)))
    assert late == live                     # replayed prefix
    got = h.result()
    assert [t for _, t, _ in live] == got
    assert live == late
    assert [i for i, _, _ in live] == list(range(req.max_new_tokens))
    assert [f for _, _, f in live] == [False] * (req.max_new_tokens - 1) \
        + [True]


def test_step_events_reconstruct_results(setup):
    """step()'s TokenEvents are a faithful stream: per-uid tokens in index
    order reconstruct the results, with exactly one final event each."""
    cfg, model, params = setup
    reqs = _requests(cfg, 4, seed=4)
    eng = ServeEngine(model, params, RT_DENSE, max_batch=2, max_len=64,
                      decode_chunk=3)
    for r in reqs:
        eng.submit(r)
    events = []
    while eng.has_work:
        events.append(eng.step())
    flat = [ev for round_ in events for ev in round_]
    by_uid = {}
    for ev in flat:
        assert ev.index == len(by_uid.setdefault(ev.uid, []))
        by_uid[ev.uid].append(ev.token)
    assert by_uid == eng.results
    assert sorted(ev.uid for ev in flat if ev.final) == [r.uid for r in reqs]


def test_handle_clocks_and_queue_wait(setup):
    """QUEUED -> RUNNING -> FINISHED clock stamps: a request that waits for
    a slot records a positive queue wait in decode-step ticks."""
    cfg, model, params = setup
    reqs = _requests(cfg, 3, seed=5, budget=lambda i: 4)
    eng = ServeEngine(model, params, RT_DENSE, max_batch=2, max_len=64,
                      decode_chunk=2)
    handles = [eng.submit(r) for r in reqs]
    eng.drain()
    assert all(h.done and h.finished_at is not None for h in handles)
    assert handles[0].queue_wait == 0.0 and handles[1].queue_wait == 0.0
    assert handles[2].queue_wait > 0.0     # waited for a freed slot


# ------------------------------------------------------- scheduler edge cases
def test_empty_queue_step_is_noop(setup):
    cfg, model, params = setup
    for cls in (ServeEngine, BatchServeEngine):
        eng = cls(model, params, RT_DENSE, max_batch=2, max_len=32)
        assert eng.step() == []
        assert not eng.has_work
        assert eng.stats.decode_steps == 0 and eng.stats.prefills == 0
        assert eng.drain() == {}


def test_admission_into_slot_freed_mid_chunk(setup):
    """A slot whose budget exhausts MID-chunk is freed at the chunk
    boundary and re-admits the next waiting request — exactly one prefill
    per request, same slot reused, outputs identical to solo runs."""
    cfg, model, params = setup
    budgets = [3, 10, 4]                  # uid 0 dies at step 2 of chunk 0
    reqs = _requests(cfg, 3, seed=6, plen=lambda i: 4,
                     budget=lambda i: budgets[i])
    eng = ServeEngine(model, params, RT_DENSE, max_batch=2, max_len=64,
                      decode_chunk=4)
    handles = [eng.submit(r) for r in reqs]
    eng.drain()
    got = eng.results
    assert eng.stats.prefills == 3
    assert handles[2].slot is None and handles[2].done
    assert handles[2].admitted_at > 0     # admitted after a freed chunk
    solo = ServeEngine(model, params, RT_DENSE, max_batch=1, max_len=64,
                       decode_chunk=4)
    want = solo.run(reqs)
    assert got == want


def test_duplicate_uid_rejected_on_both_engines(setup):
    cfg, model, params = setup
    r = Request(uid=9, prompt=np.array([1, 2], np.int32), max_new_tokens=2)
    for cls in (ServeEngine, BatchServeEngine):
        eng = cls(model, params, RT_DENSE, max_batch=2, max_len=32)
        eng.submit(r)
        with pytest.raises(ValueError, match="already submitted"):
            eng.submit(dataclasses.replace(r))


def test_zero_budget_request_rejected(setup):
    cfg, model, params = setup
    r = Request(uid=0, prompt=np.array([1], np.int32), max_new_tokens=0)
    for cls in (ServeEngine, BatchServeEngine):
        eng = cls(model, params, RT_DENSE, max_batch=2, max_len=32)
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.submit(r)
        assert not eng.has_work and eng.step() == []


def test_callback_exception_does_not_wedge_engine(setup):
    """A user on_token callback that raises must surface the error WITHOUT
    desyncing host slot bookkeeping from the already-advanced device
    state: the engine keeps serving and every request still completes with
    the exact same tokens as a callback-free engine."""
    cfg, model, params = setup
    reqs = _requests(cfg, 2, seed=20, budget=lambda i: 5)
    for cls in (ServeEngine, BatchServeEngine):
        eng = cls(model, params, RT_DENSE, max_batch=2, max_len=64)
        h0 = eng.submit(reqs[0])
        h1 = eng.submit(reqs[1])

        def cb(ev):
            raise RuntimeError("boom")

        h0.on_token(cb)
        with pytest.raises(RuntimeError, match="boom"):
            while eng.has_work:
                eng.step()
        while eng.has_work:           # resume after the error: no wedge,
            try:                      # no duplicate or lost tokens
                eng.step()
            except RuntimeError:
                pass
        assert h0.done and h1.done
        ref = cls(model, params, RT_DENSE, max_batch=2, max_len=64)
        want = ref.run(reqs)
        assert {0: h0.tokens, 1: h1.tokens} == want


def test_retire_drops_host_state_and_releases_uid(setup):
    """retire(uid) is the long-running server's memory bound: it drops the
    FINISHED handle + results entry and frees the uid for resubmission;
    live or unknown uids refuse."""
    cfg, model, params = setup
    req = Request(uid=0, prompt=np.array([1, 2], np.int32), max_new_tokens=2)
    for cls in (ServeEngine, BatchServeEngine):
        eng = cls(model, params, RT_DENSE, max_batch=2, max_len=32)
        h = eng.submit(dataclasses.replace(req))
        with pytest.raises(RuntimeError, match="only FINISHED"):
            eng.retire(0)
        eng.drain()
        toks = eng.retire(0)
        assert toks == h.tokens and len(toks) == 2
        assert 0 not in eng.handles and 0 not in eng.results
        with pytest.raises(KeyError):
            eng.retire(0)
        h2 = eng.submit(dataclasses.replace(req))   # uid released for reuse
        eng.drain()
        assert h2.tokens == toks                    # same engine, same state


def test_batch_run_validates_all_before_queueing(setup):
    """BatchServeEngine.run keeps the historical all-or-nothing contract:
    a bad request anywhere in the list raises before ANY request is queued
    or its uid burned."""
    cfg, model, params = setup
    good = Request(uid=0, prompt=np.array([1, 2], np.int32),
                   max_new_tokens=2)
    bad = Request(uid=1, prompt=np.zeros(0, np.int32), max_new_tokens=2)
    eng = BatchServeEngine(model, params, RT_DENSE, max_batch=2, max_len=32)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.run([good, bad])
    assert not eng.has_work              # nothing queued
    with pytest.raises(ValueError, match="already submitted"):
        eng.run([good, dataclasses.replace(good)])   # intra-list duplicate
    assert not eng.has_work
    out = eng.run([good])                # uid was never burned
    assert len(out[0]) == 2


# ------------------------------------------------------------------ SLO policy
def test_slo_policy_selection_order():
    """Tightest slack first: slack = deadline - age - max_new * tier cost;
    deadline-less requests are best-effort FIFO."""
    pol = SLOPolicy(tier_costs={"hi": 4.0, "lo": 1.0})
    r_loose = Request(uid=0, prompt=np.array([1]), max_new_tokens=8,
                      tier="hi", deadline=100.0)       # slack 100-32 = 68
    r_tight = Request(uid=1, prompt=np.array([1]), max_new_tokens=8,
                      tier="lo", deadline=10.0)        # slack 10-8 = 2
    r_none = Request(uid=2, prompt=np.array([1]), max_new_tokens=8,
                     tier="lo")                        # slack inf
    at = {0: 0.0, 1: 0.0, 2: 0.0}
    assert pol.select([r_loose, r_tight, r_none], at, now=0.0) == 1
    # Cost pricing: the SAME deadline bites earlier on an expensive tier.
    r_hi = dataclasses.replace(r_loose, uid=3, deadline=40.0)  # slack 8
    r_lo = dataclasses.replace(r_tight, uid=4, deadline=40.0)  # slack 32
    assert pol.select([r_lo, r_hi], {3: 0.0, 4: 0.0}, now=0.0) == 1
    # Without deadlines the policy degrades to FIFO (submission order).
    at2 = {5: 0.0, 6: 1.0}
    a = dataclasses.replace(r_none, uid=6)
    b = dataclasses.replace(r_none, uid=5)
    assert pol.select([a, b], at2, now=5.0) == 1
    assert FIFOPolicy().select([a, b], at2, now=5.0) == 0
    # Fully equal slack AND submission clock: ties break on QUEUE position
    # (the documented FIFO contract), never on uid.
    c = dataclasses.replace(r_tight, uid=9)
    d = dataclasses.replace(r_tight, uid=2)
    assert pol.select([c, d], {9: 0.0, 2: 0.0}, now=0.0) == 0


def test_slo_policy_costs_from_schedule(tiered):
    """Admission pricing comes from the hwmodel: the 8/8 tier costs more
    cycles per token than 2/2 (normalized to the cheapest = 1.0)."""
    cfg, model, params, sched, rt = tiered
    pol = SLOPolicy(sched)
    assert pol.cost("2/2") == 1.0
    assert pol.cost("8/8") > 1.0


def test_slo_admission_jumps_tight_deadline(setup):
    """Engine-level: with one slot, SLO admission serves the
    tight-deadline request first even though it was submitted last; FIFO
    serves submission order."""
    cfg, model, params = setup
    reqs = _requests(cfg, 3, seed=7, budget=lambda i: 4,
                     deadline=lambda i: 100.0 if i < 2 else 6.0)
    fifo = ServeEngine(model, params, RT_DENSE, max_batch=1, max_len=64,
                       decode_chunk=2)
    hf = [fifo.submit(r) for r in reqs]
    fifo.drain()
    slo = ServeEngine(model, params, RT_DENSE, max_batch=1, max_len=64,
                      decode_chunk=2, scheduler_policy=SLOPolicy())
    hs = [slo.submit(r) for r in reqs]
    slo.drain()
    # Same tokens either way (admission order never changes per-request
    # results on this engine), but the tight request waits far less.
    assert slo.results == fifo.results
    assert hs[2].admitted_at == 0.0        # jumped the queue
    assert hf[2].admitted_at > hf[1].admitted_at
    assert hs[2].queue_wait < hf[2].queue_wait


# ----------------------------------------------------------- tier migration
def test_set_tier_validation(setup, tiered):
    cfg, model, params = setup
    _, _, _, sched, rt = tiered
    # Untiered engine: no tiers to migrate between.
    eng = ServeEngine(model, params, RT_DENSE, max_batch=1, max_len=32)
    h = eng.submit(Request(uid=0, prompt=np.array([1, 2], np.int32),
                           max_new_tokens=2))
    with pytest.raises(ValueError, match="PrecisionSchedule"):
        h.set_tier("8/8")
    # Tiered engine: unknown tier / finished handle.
    eng2 = ServeEngine(model, params, rt, max_batch=1, max_len=32)
    h2 = eng2.submit(Request(uid=0, prompt=np.array([1, 2], np.int32),
                             max_new_tokens=2, tier="8/8"))
    with pytest.raises(ValueError, match="unknown tier"):
        h2.set_tier("3/3")
    eng2.drain()
    with pytest.raises(RuntimeError, match="finished"):
        h2.set_tier("2/2")
    # Serialized mode: RUNNING migration unsupported (QUEUED retag is fine).
    eng3 = ServeEngine(model, eng2.params, rt, max_batch=1, max_len=32,
                       mixed_tiers=False, decode_chunk=2)
    h3 = eng3.submit(Request(uid=0, prompt=np.array([1, 2], np.int32),
                             max_new_tokens=8, tier="8/8"))
    h4 = eng3.submit(Request(uid=1, prompt=np.array([1, 2], np.int32),
                             max_new_tokens=4, tier="8/8"))
    h4.set_tier("2/2")                     # queued: allowed
    assert h4.tier == "2/2"
    eng3.step()
    with pytest.raises(RuntimeError, match="mixed_tiers"):
        h3.set_tier("2/2")
    # Reference engine: never.
    base = BatchServeEngine(model, eng2.params, rt, max_batch=1, max_len=32)
    hb = base.submit(Request(uid=0, prompt=np.array([1, 2], np.int32),
                             max_new_tokens=2, tier="8/8"))
    with pytest.raises(RuntimeError, match="pins one tier"):
        hb.set_tier("2/2")


def test_set_tier_queued_retags_and_reprices(tiered):
    """A QUEUED set_tier re-tags the waiting request: it prefills at the
    new tier and its tokens match a request submitted at that tier
    directly."""
    cfg, model, params, sched, rt = tiered
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, size=5)
    eng = ServeEngine(model, params, rt, max_batch=1, max_len=64,
                      decode_chunk=2)
    blocker = eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=2,
                                 tier="8/8"))
    h = eng.submit(Request(uid=1, prompt=prompt, max_new_tokens=4,
                           tier="8/8"))
    h.set_tier("2/2")                      # still queued behind the blocker
    assert h.status is RequestStatus.QUEUED and h.tier == "2/2"
    eng.drain()
    ref = ServeEngine(model, eng.params, rt, max_batch=1, max_len=64,
                      decode_chunk=2)
    want = ref.run([Request(uid=1, prompt=prompt, max_new_tokens=4,
                            tier="2/2")])
    assert eng.results[1] == want[1]
    assert eng.stats.tier_migrations == 0  # queued retag is not a migration


def _migration_run(tiered, *, capture):
    """Drive one mid-stream bf16 -> int4 migration; ``capture(eng, h)`` is
    called right after set_tier with the engine in the migrated state."""
    cfg, model, params, sched, rt = tiered
    rng = np.random.default_rng(12)
    eng = ServeEngine(model, params, rt, max_batch=2, max_len=64,
                      decode_chunk=2)
    h = eng.submit(Request(uid=0,
                           prompt=rng.integers(0, cfg.vocab_size, size=5),
                           max_new_tokens=12, tier="8/8"))
    eng.step()
    eng.step()                             # some decode progress at 8/8
    assert h.status is RequestStatus.RUNNING
    pre = eng.arena.caches                 # immutable arrays: safe snapshot
    h.set_tier("2/2")
    assert eng.stats.tier_migrations == 1 and eng.stats.kv_migrations == 1
    capture(eng, h, pre)
    return eng, h


def test_migration_kv_lane_bit_identity(tiered):
    """The migrated slot's KV lane must be bit-identical to quantizing the
    slot's dequantized cache directly at the target precision (and every
    other slot must be untouched).

    The reference runs under an INDEPENDENT jit (a fresh trace of
    dequantize -> encode on the pre-migration snapshot): the engine's
    migration must reproduce it bit-for-bit across separate compilations —
    the ``optimization_barrier`` contract that pins the continuous-scale
    subgraphs (eager execution is outside that contract; see
    ``models/layers.py::_kv_quant``)."""
    sched = tiered[3]
    code = sched.kv_code_for("2/2")
    assert code == 4

    @jax.jit
    def direct_requantize(pre, slot, code):
        sub = slots_lib.slot_view(pre, slot)
        sub = jax.tree.map(
            lambda c: c.requantize(code)
            if isinstance(c, KVCache) and c.mixed else c,
            sub, is_leaf=lambda c: isinstance(c, KVCache))
        return slots_lib.slot_write(pre, sub, slot)

    def capture(eng, h, pre):
        want = direct_requantize(pre, h.slot, code)
        for got_l, want_l in zip(jax.tree.leaves(eng.arena.caches),
                                 jax.tree.leaves(want)):
            np.testing.assert_array_equal(np.asarray(got_l),
                                          np.asarray(want_l))

    _migration_run(tiered, capture=capture)


def test_migration_continuation_matches_fresh_engine(tiered):
    """After migration, subsequent tokens must be token-identical to a
    FRESH engine resumed from the migrated state at the new tier (fresh jit
    traces — the migrated state is self-contained)."""
    cfg, model, params, sched, rt = tiered
    snap = {}

    def capture(eng, h, pre):
        state = eng.scheduler.slots[h.slot]
        snap.update(caches=eng.arena.caches, slot=h.slot,
                    tok=eng._tok.copy(), remaining=eng._remaining.copy(),
                    emitted=len(state.tokens), request=state.request)

    eng, h = _migration_run(tiered, capture=capture)
    tail_a = h.result()[snap["emitted"]:]
    assert tail_a                           # migration happened mid-stream

    fresh = ServeEngine(model, eng.params, rt, max_batch=2, max_len=64,
                        decode_chunk=2)
    slot = snap["slot"]
    req = dataclasses.replace(snap["request"])   # tier already "2/2"
    fresh.arena.caches = snap["caches"]
    fresh.arena.tiers[slot] = req.tier
    fresh.scheduler.slots[slot] = SlotState(
        request=req, tokens=[0] * snap["emitted"],
        remaining=req.max_new_tokens - snap["emitted"])
    fresh._tok = snap["tok"].copy()
    fresh._remaining = snap["remaining"].copy()
    fresh._seen_uids.add(req.uid)
    hb = RequestHandle(req, fresh)
    hb._mark_admitted(slot, 0.0)
    fresh.handles[req.uid] = hb
    tail_b = []
    while fresh.has_work:
        tail_b.extend(ev.token for ev in fresh.step())
    assert tail_b == tail_a


def test_migration_token_parity_same_kv_tier(tiered):
    """Migrating between tiers that SHARE a KV precision is a pure weight
    plane-prefix switch: the KV arena is left byte-for-byte untouched (no
    requantization) and decoding completes at the new tier."""
    cfg, model, params, _, _ = tiered
    sched = uniform_schedule({"8/8": (8, 8), "4/4": (4, 4)},
                             kv_tiers={"8/8": 8, "4/4": 8})
    rt = Runtime(policy=sched.policy_for(), mode="serve", moe_dropless=True,
                 schedule=sched)
    rng = np.random.default_rng(13)
    eng = ServeEngine(model, params, rt, max_batch=1, max_len=64,
                      decode_chunk=2)
    h = eng.submit(Request(uid=0,
                           prompt=rng.integers(0, cfg.vocab_size, size=4),
                           max_new_tokens=8, tier="8/8"))
    eng.step()
    pre = eng.arena.caches
    h.set_tier("4/4")
    assert eng.stats.tier_migrations == 1
    assert eng.stats.kv_migrations == 0      # same kv precision: no requant
    for a, b in zip(jax.tree.leaves(eng.arena.caches), jax.tree.leaves(pre)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    h.result()
    assert len(eng.results[0]) == 8
