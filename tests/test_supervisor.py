"""Heartbeat supervisor: dead-node detection, straggler eviction, re-mesh."""
from repro.launch.supervisor import Supervisor, SupervisorConfig


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _fleet(n=4, timeout=10.0, patience=2):
    clock = FakeClock()
    sup = Supervisor(SupervisorConfig(heartbeat_timeout_s=timeout,
                                      straggler_factor=2.0,
                                      straggler_patience=patience,
                                      min_workers=1), clock=clock)
    for i in range(n):
        sup.register(i)
    return sup, clock


def test_dead_node_evicted_on_timeout():
    sup, clock = _fleet()
    for step in range(3):
        clock.t += 1.0
        for uid in (0, 1, 2):            # worker 3 goes silent
            sup.heartbeat(uid, step, 1.0)
        assert sup.check() == [] or clock.t <= 10.0
    clock.t += 11.0
    for uid in (0, 1, 2):
        sup.heartbeat(uid, 3, 1.0)
    evicted = sup.check()
    assert evicted == [3]
    assert sup.alive_workers() == [0, 1, 2]
    assert sup.generation == 1


def test_straggler_evicted_after_patience():
    sup, clock = _fleet(patience=2)
    evictions = []
    for step in range(4):
        clock.t += 1.0
        for uid in range(4):
            t = 5.0 if uid == 2 else 1.0     # worker 2 runs 5x slower
            sup.heartbeat(uid, step, t)
        evictions += sup.check()
    assert evictions == [2]
    assert 2 not in sup.alive_workers()


def test_fast_fleet_not_evicted():
    sup, clock = _fleet()
    for step in range(5):
        clock.t += 1.0
        for uid in range(4):
            sup.heartbeat(uid, step, 1.0 + 0.1 * uid)   # mild skew only
        assert sup.check() == []
    assert sup.alive_workers() == [0, 1, 2, 3]


def test_remesh_plan_after_eviction():
    sup, clock = _fleet()
    for step in range(3):
        clock.t += 1.0
        for uid in (0, 1, 2):
            sup.heartbeat(uid, step, 1.0)
    clock.t += 20.0
    for uid in (0, 1, 2):
        sup.heartbeat(uid, 3, 1.0)
    sup.check()
    plan = sup.remesh_plan(chips_per_worker=4)
    assert plan["workers"] == [0, 1, 2]
    assert plan["n_chips"] == 12
    assert plan["resume_step"] == 3
    assert plan["generation"] == 1


def test_min_workers_floor():
    sup, clock = _fleet(n=2)
    sup.cfg = SupervisorConfig(heartbeat_timeout_s=1.0, min_workers=2)
    clock.t += 100.0                    # everyone times out...
    assert sup.check() == []            # ...but the floor holds the fleet
    assert len(sup.alive_workers()) == 2
