"""End-to-end system behaviour: QAT train -> checkpoint -> restore ->
quantize -> serve, under a mixed-precision policy (the paper's workflow)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs import reduced_config
from repro.core.policy import (LayerPrecision, PrecisionPolicy,
                               allocate_bits_by_sensitivity, uniform_policy)
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.serve.engine import Request, ServeEngine, prepare_params
from repro.train import optimizer as optim
from repro.train.step import make_train_step


def test_full_lifecycle(tmp_path):
    cfg = reduced_config("qwen3-8b")
    model = LM(cfg)

    # 1) Mixed-precision policy: attention 6-bit, MLP 4-bit, head 8-bit.
    policy = PrecisionPolicy(rules={
        "layers.*.attn.*": LayerPrecision(6, 8, backend="fake_quant"),
        "layers.*.mlp.*": LayerPrecision(4, 8, backend="fake_quant"),
        "lm_head": LayerPrecision(8, 8, backend="fake_quant"),
    }, default=LayerPrecision(8, 8, backend="fake_quant"))
    rt = Runtime(policy=policy)

    # 2) QAT training.
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=24,
                                  global_batch=8, task="arith"))
    ocfg = optim.OptConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                           weight_decay=0.0)
    step = jax.jit(make_train_step(model, rt, ocfg))
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": optim.init_state(params, ocfg)}
    first = last = None
    for i in range(30):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        state, m = step(state, b)
        first = first if first is not None else float(m["ce"])
        last = float(m["ce"])
    assert last < first

    # 3) Checkpoint + restore.
    ckpt.save(str(tmp_path), 30, state, extra={"data_step": 30})
    target = {"params": params, "opt": optim.init_state(params, ocfg)}
    state2, extra = ckpt.restore(str(tmp_path), 30, target)
    assert extra["data_step"] == 30

    # 4) Offline quantization to decomposed planes (serving form) and
    #    greedy decoding through the batch engine.
    serve_policy = policy.with_backend("decomposed")
    prepared, qpaths = prepare_params(state2["params"], serve_policy, model)
    assert qpaths
    rt_serve = Runtime(policy=serve_policy, mode="serve", moe_dropless=True)
    eng = ServeEngine(model, prepared, rt_serve, max_batch=2, max_len=64)
    prompt = np.asarray(data.batch(99)["tokens"][0][:8])
    out = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=6)])
    assert len(out[0]) == 6

    # 5) The trained mixed-precision model beats an untrained one on the
    #    serving (integer) path: CE on a held-out batch.
    from repro.train.step import make_loss_fn
    loss_fn = make_loss_fn(model, rt_serve)
    held = {k: jnp.asarray(v) for k, v in data.batch(1234).items()}
    trained_loss = float(loss_fn(prepared, held)[0])
    fresh, _ = prepare_params(model.init(jax.random.PRNGKey(9)),
                              serve_policy, model)
    fresh_loss = float(loss_fn(fresh, held)[0])
    assert trained_loss < fresh_loss


def test_sensitivity_allocator_budget():
    sens = {"a": 10.0, "b": 1.0, "c": 0.1}
    counts = {"a": 100, "b": 100, "c": 100}
    pol = allocate_bits_by_sensitivity(sens, counts, avg_bits=4.0)
    bits = {n: pol.lookup(n).w_bits for n in sens}
    assert bits["a"] >= bits["b"] >= bits["c"]
    assert pol.average_bits(sens, [counts[n] for n in sens]) <= 4.0 + 1e-6


def test_policy_pattern_matching():
    pol = PrecisionPolicy(rules={"layers.*.attn.*": LayerPrecision(2, 2)},
                          default=LayerPrecision(8, 8))
    assert pol.lookup("layers.pos0.attn.q_proj").w_bits == 2
    assert pol.lookup("layers.pos0.mlp.up_proj").w_bits == 8
    assert pol.with_backend("pallas").lookup("x").backend == "pallas"
