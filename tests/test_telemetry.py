"""repro.telemetry: the two contracts plus the exporter schemas.

* zero-cost-when-off — a ``telemetry=None`` engine drains a full mixed-tier
  stream without one hook call (module-level ``HOOK_CALLS`` spy) and
  without one host fence (``jax.block_until_ready`` is monkeypatched to
  raise for the whole drain);
* bitwise stability when on — telemetry with device profiling (a real
  fence per dispatch) leaves every stream token-identical, for mixed
  tiers, speculative decoding, and a 2-device mesh engine (subprocess);
* exporters — the Chrome trace validates against the trace-event schema
  (required keys, monotone ``ts`` per track) and the Prometheus text
  round-trips bit-exactly through the companion parser.
"""
import json

import jax
import numpy as np
import pytest

import repro.telemetry as telemetry_mod
from repro.configs import reduced_config
from repro.core.policy import uniform_schedule
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.serve import Request, ServeEngine, SpecConfig
from repro.serve.engine import EngineStats
from repro.telemetry import (SECONDS_BUCKETS, Histogram, MetricsRegistry,
                             Telemetry, Tracer, format_group_layout,
                             parse_prometheus, serve_report,
                             sync_engine_stats, to_prometheus)
from test_sharded_serving import run_subprocess

TIERS = {"8/8": (8, 8), "4/4": (4, 4), "2/2": (2, 2)}


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = uniform_schedule(TIERS, kv_tiers={"8/8": None, "4/4": 8,
                                              "2/2": 4})
    rt = Runtime(policy=sched.policy_for(), mode="serve", moe_dropless=True,
                 schedule=sched)
    return cfg, model, params, rt


def _requests(cfg, n=6, seed=13, **extra):
    rng = np.random.default_rng(seed)
    names = list(TIERS)
    return [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               size=3 + i % 4),
                    max_new_tokens=5 + i % 3, tier=names[i % 3], **extra)
            for i in range(n)]


# ------------------------------------------------------------- primitives
def test_histogram_quantiles_interpolate():
    h = Histogram("h", "", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 1.5, 3.0):
        h.observe(v)
    assert h.count == 4 and h.sum == pytest.approx(6.5)
    assert h.mean() == pytest.approx(6.5 / 4)
    # counts: [1 (<=1), 2 (<=2), 1 (<=4), 0 (+Inf)]
    assert h.counts == [1, 2, 1, 0]
    assert h.quantile(0.0) == 0.0
    # target 2.0 lands in the (1, 2] bucket: 1 + (2-1)/2 * (2-1) = 1.5
    assert h.quantile(0.5) == pytest.approx(1.5)
    assert h.quantile(1.0) == pytest.approx(4.0)
    h.observe(100.0)                      # overflow bucket degenerates
    assert h.quantile(1.0) == pytest.approx(4.0)
    assert Histogram("e", "").quantile(0.99) == 0.0


def test_histogram_rejects_bad_buckets():
    with pytest.raises(ValueError, match="ascending"):
        Histogram("h", "", buckets=(2.0, 1.0))
    with pytest.raises(ValueError, match="Inf"):
        Histogram("h", "", buckets=(1.0, float("inf")))
    with pytest.raises(ValueError, match="outside"):
        Histogram("h", "", buckets=(1.0,)).quantile(1.5)


def test_registry_idempotent_and_kind_clash():
    r = MetricsRegistry()
    c = r.counter("serve_x", "first")
    assert r.counter("serve_x", "second") is c
    with pytest.raises(ValueError, match="already registered"):
        r.gauge("serve_x")
    c.inc(2.0)
    assert r.value("serve_x") == 2.0
    with pytest.raises(ValueError, match="negative"):
        c.inc(-1.0)
    r.histogram("serve_h", "")
    with pytest.raises(TypeError, match="histogram"):
        r.value("serve_h")
    g = r.gauge("serve_by_tier", labels=("tier",))
    g.set(3.0, tier="4/4")
    assert r.value("serve_by_tier", tier="4/4") == 3.0
    assert r.value("serve_by_tier", tier="2/2") == 0.0
    with pytest.raises(ValueError, match="expected labels"):
        g.set(1.0, wrong="x")
    assert r.value("never_registered") == 0.0


def test_sync_engine_stats_twins():
    stats = EngineStats()
    stats.prefills = 3
    stats.decode_steps = 17
    stats.decode_steps_by_tier["4/4"] = 9
    stats.tokens_by_tier["2/2"] = 5
    stats.decode_dispatches[(("8/8", 2), ("4/4", 1))] = 8
    r = MetricsRegistry()
    sync_engine_stats(r, stats)
    assert r.value("serve_prefills") == 3.0
    assert r.value("serve_decode_steps") == 17.0
    assert r.value("serve_decode_steps_by_tier", tier="4/4") == 9.0
    assert r.value("serve_tokens_by_tier", tier="2/2") == 5.0
    assert r.value("serve_decode_dispatches", layout="8/8x2+4/4x1") == 8.0
    # re-sync after mutation: twins follow, nothing double-counts
    stats.decode_steps = 18
    sync_engine_stats(r, stats)
    assert r.value("serve_decode_steps") == 18.0


def test_format_group_layout():
    assert format_group_layout((("8/8", 2), ("4/4", 1))) == "8/8x2+4/4x1"
    assert format_group_layout(()) == ""


# -------------------------------------------------------------- exporters
def test_prometheus_roundtrip_bit_exact():
    r = MetricsRegistry()
    r.counter("serve_total", "a\ncounter").inc(0.1 + 0.2)  # non-terminating
    r.gauge("serve_ratio").set(1e-17)
    lab = r.counter("serve_by_tier", labels=("tier",))
    lab.inc(3.0, tier='we"ird\\tier\n')                    # escaping
    h = r.histogram("serve_lat", "latency", buckets=(1.0, 8.0))
    for v in (0.5, 4.0, 99.0):
        h.observe(v)
    text = to_prometheus(r)
    assert "# TYPE serve_lat histogram" in text
    parsed = parse_prometheus(text)
    assert parsed["serve_total"][()] == 0.1 + 0.2          # bit-exact
    assert parsed["serve_ratio"][()] == 1e-17
    assert parsed["serve_by_tier"][(("tier", 'we"ird\\tier\n'),)] == 3.0
    buckets = parsed["serve_lat_bucket"]
    assert buckets[(("le", "1.0"),)] == 1.0                # cumulative
    assert buckets[(("le", "8.0"),)] == 2.0
    assert buckets[(("le", "+Inf"),)] == 3.0
    assert parsed["serve_lat_count"][()] == 3.0
    assert parsed["serve_lat_sum"][()] == 103.5
    with pytest.raises(ValueError, match="unparseable"):
        parse_prometheus("this is not a metric line")


def test_tracer_schema_and_monotone_tracks(tmp_path):
    tr = Tracer()
    tr.request_phase(0, "queued", ticks=0.0)
    tr.request_phase(1, "queued", ticks=0.0)
    t0 = tr.now()
    tr.dispatch("prefill", t0, ticks=0.0, ticks_end=0.0, args={"uid": 0})
    tr.request_phase(0, "running", ticks=0.0)
    tr.dispatch("decode_chunk", tr.now(), ticks=0.0, ticks_end=4.0,
                args={"n_steps": 4})
    tr.engine_instant("preempt", ticks=4.0, args={"uid": 0})
    tr.request_phase(0, "suspended", ticks=4.0)
    tr.request_end(0, "finished", ticks=8.0)
    tr.request_end(1, "shed", ticks=8.0)
    path = tmp_path / "trace.json"
    tr.write(str(path))
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    assert events, "trace must not be empty"
    for ev in events:
        assert {"name", "ph", "pid", "tid"} <= set(ev), ev
        assert ev["pid"] == 1
        assert ev["ph"] in ("X", "i", "M")
        if ev["ph"] != "M":               # metadata events carry no ts
            assert "ts" in ev, ev
        if ev["ph"] == "X":
            assert ev["dur"] >= 0
        if ev["ph"] == "i":
            assert ev["s"] == "t"
    body = [ev for ev in events if ev["ph"] != "M"]
    by_track = {}
    for ev in body:
        by_track.setdefault(ev["tid"], []).append(ev["ts"])
    assert set(by_track) == {0, 1, 2}      # engine + one track per uid
    for tid, stamps in by_track.items():
        assert stamps == sorted(stamps), f"track {tid} ts not monotone"
    names = {(ev["tid"], ev["name"]) for ev in body}
    for want in [(0, "prefill"), (0, "decode_chunk"), (0, "preempt"),
                 (1, "queued"), (1, "running"), (1, "suspended"),
                 (1, "finished"), (2, "queued"), (2, "shed")]:
        assert want in names, f"missing event {want}"


# ------------------------------------------------------ engine contracts
def test_zero_cost_when_off(setup, monkeypatch):
    """A telemetry-less engine takes no hooks and no host fences."""
    cfg, model, params, rt = setup

    def forbidden(*a, **k):
        raise AssertionError("engine fenced the device without telemetry")

    monkeypatch.setattr(jax, "block_until_ready", forbidden)
    eng = ServeEngine(model, params, rt, max_batch=3, max_len=64,
                      decode_chunk=4)
    before = telemetry_mod.HOOK_CALLS
    out = eng.run(_requests(cfg))
    assert sum(len(v) for v in out.values()) > 0
    assert telemetry_mod.HOOK_CALLS == before, \
        "telemetry-off engine called observability hooks"


def test_token_identity_mixed_tiers(setup, tmp_path):
    """Profiled telemetry (a fence per dispatch) changes no tokens, the
    EngineStats twins agree, latency histograms cover every request, and
    the report + exporters render from the same registry."""
    cfg, model, params, rt = setup
    off = ServeEngine(model, params, rt, max_batch=3, max_len=64,
                      decode_chunk=4)
    got_off = off.run(_requests(cfg))

    tele = Telemetry(profile=True)
    on = ServeEngine(model, off.params, rt, max_batch=3, max_len=64,
                     decode_chunk=4, telemetry=tele)
    got_on = on.run(_requests(cfg))
    assert got_on == got_off

    reg = tele.registry
    import dataclasses
    for f in dataclasses.fields(on.stats):
        v = getattr(on.stats, f.name)
        if isinstance(v, int):
            assert reg.value("serve_" + f.name) == float(v), f.name
    for tier, n in on.stats.decode_steps_by_tier.items():
        assert reg.value("serve_decode_steps_by_tier",
                         tier=tier) == float(n)
    n = len(got_on)
    assert reg.get("serve_queue_wait_ticks").count == n
    assert reg.get("serve_ttft_ticks").count == n
    assert reg.get("serve_tpot_ticks").count == n
    assert reg.get("serve_ttft_seconds").count == n
    assert 0.0 < reg.value("serve_slot_utilization") <= 1.0
    assert 0.0 < reg.value("serve_modeled_cycle_utilization") <= 1.0

    prof = tele.profiler.snapshot()
    assert prof["phases"]["prefill"]["calls"] == on.stats.prefills
    assert prof["phases"]["decode_chunk"]["calls"] == on.stats.decode_chunks
    assert prof["phases"]["decode_chunk"]["total_s"] > 0.0

    # every export path renders off the same state
    report = serve_report(reg, tiers=list(TIERS))
    assert "slot_util=" in report and "ttft" in report
    parsed = parse_prometheus(tele.prometheus())
    assert parsed["serve_decode_steps"][()] == float(on.stats.decode_steps)
    path = tmp_path / "trace.json"
    tele.write_trace(str(path))
    events = json.loads(path.read_text())["traceEvents"]
    tracks = {ev["tid"] for ev in events if ev["ph"] != "M"}
    assert tracks == {0} | {uid + 1 for uid in got_on}
    snap = tele.snapshot()
    assert snap["metrics"]["serve_ttft_ticks"]["count"] == n
    assert snap["profile"]["phases"]["prefill"]["calls"] == on.stats.prefills


def test_token_identity_speculative(setup):
    """Telemetry through the speculative engine: token-identical, spec
    counters mirrored, acceptance-rate gauge consistent."""
    cfg, model, params, rt0 = setup
    sched = uniform_schedule(TIERS, kv_tiers={"8/8": 8, "4/4": 8,
                                              "2/2": 8})
    rt = Runtime(policy=sched.policy_for(), mode="serve", moe_dropless=True,
                 schedule=sched)
    reqs = dict(n=4, seed=7, spec=SpecConfig(draft_tier="2/2", k=2))
    off = ServeEngine(model, params, rt, max_batch=2, max_len=64,
                      decode_chunk=2)
    got_off = off.run(_requests(cfg, **reqs))
    tele = Telemetry()
    on = ServeEngine(model, off.params, rt, max_batch=2, max_len=64,
                     decode_chunk=2, telemetry=tele)
    got_on = on.run(_requests(cfg, **reqs))
    assert got_on == got_off
    assert on.stats.spec_rounds > 0
    reg = tele.registry
    assert reg.value("serve_spec_rounds") == float(on.stats.spec_rounds)
    assert reg.value("serve_spec_accepted") == float(on.stats.spec_accepted)
    rate = reg.value("serve_spec_acceptance_rate")
    assert rate == pytest.approx(
        on.stats.spec_accepted / on.stats.spec_drafted)
    assert "speculate: rounds=" in serve_report(reg, speculate=True)


def test_deadline_miss_counter(setup):
    """serve_deadline_misses is telemetry-owned: an impossible deadline
    counts once, a generous one doesn't."""
    cfg, model, params, rt = setup
    tele = Telemetry()
    eng = ServeEngine(model, params, rt, max_batch=2, max_len=64,
                      decode_chunk=4, telemetry=tele)
    reqs = _requests(cfg, n=2)
    reqs[0].deadline = 0.5          # < 1 tick: cannot be met
    reqs[1].deadline = 1e6
    eng.run(reqs)
    assert tele.registry.value("serve_deadline_misses") == 1.0


def test_mesh_token_identity_with_telemetry():
    """2-device mesh engine with profiled telemetry == unsharded engine
    without, token for token."""
    out = run_subprocess("""
        import jax, numpy as np
        from repro.configs import reduced_config
        from repro.core.policy import uniform_schedule
        from repro.launch.mesh import make_serve_mesh
        from repro.models.layers import Runtime
        from repro.models.transformer import LM
        from repro.serve import Request, ServeEngine
        from repro.telemetry import Telemetry

        cfg = reduced_config("qwen3-8b")
        model = LM(cfg)
        params = model.init(jax.random.PRNGKey(0))
        sched = uniform_schedule(
            {"8/8": (8, 8), "4/4": (4, 4), "2/2": (2, 2)},
            kv_tiers={"8/8": None, "4/4": 8, "2/2": 4})
        rt = Runtime(policy=sched.policy_for(), mode="serve",
                     schedule=sched)
        tiers = ["8/8", "4/4", "2/2"]

        def serve(mesh, telemetry):
            rng = np.random.default_rng(0)
            eng = ServeEngine(model, params, rt, max_batch=3, max_len=64,
                              decode_chunk=4, mesh=mesh,
                              telemetry=telemetry)
            reqs = [Request(uid=i,
                            prompt=rng.integers(0, cfg.vocab_size, size=4),
                            max_new_tokens=8, tier=tiers[i % 3])
                    for i in range(4)]
            return eng.run(reqs), eng

        ref, _ = serve(None, None)
        tele = Telemetry(profile=True)
        tp2, eng2 = serve(make_serve_mesh(2), tele)
        assert eng2._tp is not None
        assert ref == tp2, (ref, tp2)
        assert tele.registry.value("serve_decode_steps") \\
            == float(eng2.stats.decode_steps)
        assert tele.profiler.snapshot()["phases"]["decode_chunk"]["calls"] \\
            == eng2.stats.decode_chunks
        print("TELEMETRY_TP_OK", sum(len(v) for v in ref.values()))
    """)
    assert "TELEMETRY_TP_OK" in out
