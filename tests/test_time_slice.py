"""Time-slice fairness (``SLOPolicy(time_slice=N)``) + terminal-state
retirement hygiene.

Time slicing: best-effort RUNNING slots are voluntarily preempted after
N scheduler ticks whenever requests wait, so long best-effort streams
round-robin instead of holding slots to completion — and the resumed
streams stay token-identical (they ride the ordinary preempt/resume
snapshot path).  Retirement: retiring EVERY terminal request (FINISHED
and SHED alike) leaves the engine with zero per-request host state.
"""
import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.policy import uniform_schedule
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.serve import (Request, RequestStatus, ServeEngine, SLOPolicy)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = uniform_schedule({"8/8": (8, 8), "4/4": (4, 4)},
                             kv_tiers={"8/8": 8, "4/4": 8})
    rt = Runtime(policy=sched.policy_for(), mode="serve", moe_dropless=True,
                 schedule=sched)
    return cfg, model, params, sched, rt


def _reqs(cfg, n, max_new=12):
    rng = np.random.default_rng(0)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=3 + i % 3),
                    max_new_tokens=max_new, tier="8/8")
            for i in range(n)]


def test_time_slice_validation():
    with pytest.raises(ValueError, match="time_slice"):
        SLOPolicy(time_slice=0)
    with pytest.raises(ValueError, match="time_slice"):
        SLOPolicy(time_slice=-3)
    assert SLOPolicy(time_slice=4).time_slice == 4
    assert SLOPolicy().time_slice is None


def test_time_slice_round_robins_best_effort(setup):
    """3 long best-effort requests over 1 slot: with a slice every
    request starts long before the first finishes; without one, strict
    run-to-completion.  Streams stay token-identical either way."""
    cfg, model, params, sched, rt = setup
    reqs = _reqs(cfg, 3, max_new=12)

    def serve(policy):
        eng = ServeEngine(model, params, rt, max_batch=1, max_len=64,
                          decode_chunk=2, scheduler_policy=policy)
        handles = [eng.submit(Request(
            uid=r.uid, prompt=r.prompt, max_new_tokens=r.max_new_tokens,
            tier=r.tier)) for r in reqs]
        first_token_at = {}
        while eng.has_work:
            for ev in eng.step():
                if ev.index == 0:
                    first_token_at[ev.uid] = eng.clock
        return eng, handles, first_token_at

    eng_fifo, h_fifo, first_fifo = serve(None)
    sliced = SLOPolicy(sched, time_slice=4)
    eng_ts, h_ts, first_ts = serve(sliced)

    # identical streams (preempt/resume is token-identical)
    assert {h.uid: h.tokens for h in h_ts} \
        == {h.uid: h.tokens for h in h_fifo}
    assert eng_ts.stats.time_slice_preemptions > 0
    assert eng_ts.stats.resumes >= eng_ts.stats.time_slice_preemptions
    # fairness: with slicing, the LAST request's first token arrives well
    # before the FIFO run's (which waits for 2 full 12-token streams).
    assert first_ts[2] < first_fifo[2]


def test_time_slice_never_fires_without_waiters(setup):
    cfg, model, params, sched, rt = setup
    eng = ServeEngine(model, params, rt, max_batch=2, max_len=64,
                      decode_chunk=2,
                      scheduler_policy=SLOPolicy(sched, time_slice=1))
    out = eng.run(_reqs(cfg, 2, max_new=10))
    assert eng.stats.time_slice_preemptions == 0
    assert all(len(v) == 10 for v in out.values())


def test_time_slice_spares_deadlined_slots(setup):
    """Deadlined requests are never sliced: their urgency is priced by
    slack, and slicing them would burn deadline budget on fairness."""
    cfg, model, params, sched, rt = setup
    eng = ServeEngine(model, params, rt, max_batch=1, max_len=64,
                      decode_chunk=2,
                      scheduler_policy=SLOPolicy(sched, time_slice=2))
    rng = np.random.default_rng(1)
    first = eng.submit(Request(uid=0,
                               prompt=rng.integers(0, cfg.vocab_size, size=4),
                               max_new_tokens=10, tier="8/8",
                               deadline=1000.0))
    eng.step()
    waiter = eng.submit(Request(uid=1,
                                prompt=rng.integers(0, cfg.vocab_size,
                                                    size=4),
                                max_new_tokens=4, tier="8/8"))
    while eng.has_work:
        eng.step()
    assert eng.stats.time_slice_preemptions == 0
    assert len(first.tokens) == 10 and len(waiter.tokens) == 4


def test_retire_releases_every_terminal_state(setup):
    """FINISHED and SHED (cancelled mid-suspension, with policy residue)
    requests all retire to an empty engine: no handles, no snapshots, no
    scheduler or policy leftovers."""
    cfg, model, params, sched, rt = setup
    pol = SLOPolicy(sched, preempt=True)
    eng = ServeEngine(model, params, rt, max_batch=1, max_len=64,
                      decode_chunk=2, scheduler_policy=pol)
    rng = np.random.default_rng(2)
    h0 = eng.submit(Request(uid=0,
                            prompt=rng.integers(0, cfg.vocab_size, size=4),
                            max_new_tokens=8, tier="8/8"))
    eng.step()
    assert h0.status is RequestStatus.RUNNING
    sus = eng.preempt(0)
    assert h0.status is RequestStatus.SUSPENDED
    assert 0 in eng._suspended and 0 in pol.remaining_tokens
    eng.cancel(0)           # the normal suspended-state cleanup
    # Put the residue BACK to prove retire() clears it on its own — the
    # belt-and-braces path that makes "retire every terminal handle ->
    # empty engine" an invariant rather than a happy-path accident.
    eng._suspended[0] = sus
    pol.remaining_tokens[0] = 5
    h1 = eng.submit(Request(uid=1,
                            prompt=rng.integers(0, cfg.vocab_size, size=4),
                            max_new_tokens=3, tier="8/8"))
    eng.drain()
    assert h0.status is RequestStatus.SHED
    assert h1.status is RequestStatus.FINISHED
    toks0 = eng.retire(0)
    toks1 = eng.retire(1)
    assert toks0 == list(h0.tokens) and toks1 == list(h1.tokens)
    assert eng.handles == {}
    assert eng._suspended == {}
    assert pol.remaining_tokens == {}
    assert eng.results == {}
    assert eng._seen_uids == set()
    # a retired uid may be submitted again
    h2 = eng.submit(Request(uid=0,
                            prompt=rng.integers(0, cfg.vocab_size, size=4),
                            max_new_tokens=2, tier="8/8"))
    eng.drain()
    assert h2.status is RequestStatus.FINISHED
