"""Quantized manual-TP matmul block vs the unsharded reference."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(body: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_tp_mlp_matches_reference():
    out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.tp_matmul import tp_mlp_block
        mesh = jax.make_mesh((4,), ("model",))
        rng = np.random.default_rng(0)
        d, f = 64, 128
        x = rng.normal(size=(6, d)).astype(np.float32)
        w_up = (rng.normal(size=(d, f)) / np.sqrt(d)).astype(np.float32)
        w_down = (rng.normal(size=(f, d)) / np.sqrt(f)).astype(np.float32)
        got = np.asarray(tp_mlp_block(mesh, jnp.asarray(x),
                                      jnp.asarray(w_up), jnp.asarray(w_down)),
                         np.float32)
        h = np.asarray(jax.nn.gelu(
            jnp.asarray(x @ w_up, jnp.float32)), np.float32)
        want = h @ w_down
        rel = np.abs(got - want).max() / np.abs(want).max()
        # int8 activation wire + bf16 matmuls: a few percent.
        assert rel < 0.05, rel
        print("TP_MLP_OK", rel)
    """)
    assert "TP_MLP_OK" in out


def test_collectives_are_quantized():
    """The compiled shard_map block must gather int8 (s8), not f32."""
    out = run_subprocess("""
        import jax, jax.numpy as jnp, re
        from repro.distributed.tp_matmul import tp_mlp_block
        mesh = jax.make_mesh((4,), ("model",))
        d, f = 64, 128
        xs = jax.ShapeDtypeStruct((6, d), jnp.float32)
        us = jax.ShapeDtypeStruct((d, f), jnp.float32)
        ds = jax.ShapeDtypeStruct((f, d), jnp.float32)
        c = jax.jit(lambda x, u, v: tp_mlp_block(mesh, x, u, v)).lower(
            xs, us, ds).compile()
        txt = c.as_text()
        ags = [l for l in txt.splitlines() if "all-gather(" in l]
        # The activation gather is int8 on the wire (vs f32 under GSPMD —
        # §Perf J3/L1).  The remaining gathers are the tiny scale vector and
        # the test-convenience output gather.  Match on the instruction's
        # RESULT type (XLA versions differ on whether the instruction name
        # itself starts with "all-gather").
        assert any(re.search(r"= s8\\[6,64\\]\\S* all-gather\\(", l)
                   for l in ags), ags
        rs = [l for l in txt.splitlines() if "reduce-scatter(" in l]
        assert rs, "expected a psum_scatter lowering to reduce-scatter"
        print("WIRE_OK", len(ags))
    """)
    assert "WIRE_OK" in out


def test_wire_quantizer_scale_jit_stable():
    """Regression (mirrors test_act_quant_scale_jit_stable): the wire
    quantizer's scale must be bitwise identical between eager and jit.
    The original `amax / qmax` true division drifted 1 ulp under XLA
    strength-reduction, desynchronizing the wire format from the compute
    format; both now route through ref.quant_scale's reciprocal
    multiply."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.distributed.tp_matmul import _quantize_rows
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))
    qe, se = _quantize_rows(x)
    qj, sj = jax.jit(_quantize_rows)(x)
    assert np.array_equal(np.asarray(qe), np.asarray(qj))
    assert np.array_equal(np.asarray(se, np.float32),
                          np.asarray(sj, np.float32))


def test_compressed_psum_scale_jit_stable():
    """Same regression for the DP gradient compressor: the globally-agreed
    scale (pmax'd amax * reciprocal) must not depend on compilation
    context, or replicas disagree on the wire format."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.distributed.compression import compressed_psum
    from repro.distributed.sharding import shard_map

    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape((1,)), ("dp",))
    rng = np.random.default_rng(8)
    g = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    err = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32) * 1e-3)

    def body(g, e):
        return compressed_psum(g, e, axis_name="dp")

    fn = shard_map(body, mesh=mesh, in_specs=(P(), P()),
                   out_specs=(P(), P()), check_vma=False)
    me, ee = fn(g, err)
    mj, ej = jax.jit(fn)(g, err)
    # The wire-visible quantities (shared scale, integer sum -> mean grad)
    # must be BITWISE stable; the error-feedback residual is device-local
    # and may differ by an FMA contraction under jit, which EF absorbs.
    assert np.array_equal(np.asarray(me), np.asarray(mj))
    np.testing.assert_allclose(np.asarray(ee), np.asarray(ej), atol=1e-6)


def test_napkin_math():
    from repro.distributed.tp_matmul import collective_bytes_per_token
    est = collective_bytes_per_token(4096, 12288, 16)
    assert est["vs_f32"] > 3.5          # ~4x vs the CPU-promoted f32 gather
    assert est["vs_bf16"] > 1.8         # ~2x vs native-bf16 GSPMD
    assert est["vs_allreduce_f32"] == 4.0
