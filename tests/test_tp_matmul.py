"""Quantized manual-TP matmul block vs the unsharded reference."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(body: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_tp_mlp_matches_reference():
    out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.tp_matmul import tp_mlp_block
        mesh = jax.make_mesh((4,), ("model",))
        rng = np.random.default_rng(0)
        d, f = 64, 128
        x = rng.normal(size=(6, d)).astype(np.float32)
        w_up = (rng.normal(size=(d, f)) / np.sqrt(d)).astype(np.float32)
        w_down = (rng.normal(size=(f, d)) / np.sqrt(f)).astype(np.float32)
        got = np.asarray(tp_mlp_block(mesh, jnp.asarray(x),
                                      jnp.asarray(w_up), jnp.asarray(w_down)),
                         np.float32)
        h = np.asarray(jax.nn.gelu(
            jnp.asarray(x @ w_up, jnp.float32)), np.float32)
        want = h @ w_down
        rel = np.abs(got - want).max() / np.abs(want).max()
        # int8 activation wire + bf16 matmuls: a few percent.
        assert rel < 0.05, rel
        print("TP_MLP_OK", rel)
    """)
    assert "TP_MLP_OK" in out


def test_collectives_are_quantized():
    """The compiled shard_map block must gather int8 (s8), not f32."""
    out = run_subprocess("""
        import jax, jax.numpy as jnp, re
        from repro.distributed.tp_matmul import tp_mlp_block
        mesh = jax.make_mesh((4,), ("model",))
        d, f = 64, 128
        xs = jax.ShapeDtypeStruct((6, d), jnp.float32)
        us = jax.ShapeDtypeStruct((d, f), jnp.float32)
        ds = jax.ShapeDtypeStruct((f, d), jnp.float32)
        c = jax.jit(lambda x, u, v: tp_mlp_block(mesh, x, u, v)).lower(
            xs, us, ds).compile()
        txt = c.as_text()
        ags = [l for l in txt.splitlines() if "all-gather(" in l]
        # The activation gather is int8 on the wire (vs f32 under GSPMD —
        # §Perf J3/L1).  The remaining gathers are the tiny scale vector and
        # the test-convenience output gather.  Match on the instruction's
        # RESULT type (XLA versions differ on whether the instruction name
        # itself starts with "all-gather").
        assert any(re.search(r"= s8\\[6,64\\]\\S* all-gather\\(", l)
                   for l in ags), ags
        rs = [l for l in txt.splitlines() if "reduce-scatter(" in l]
        assert rs, "expected a psum_scatter lowering to reduce-scatter"
        print("WIRE_OK", len(ags))
    """)
    assert "WIRE_OK" in out


def test_napkin_math():
    from repro.distributed.tp_matmul import collective_bytes_per_token
    est = collective_bytes_per_token(4096, 12288, 16)
    assert est["vs_f32"] > 3.5          # ~4x vs the CPU-promoted f32 gather
    assert est["vs_bf16"] > 1.8         # ~2x vs native-bf16 GSPMD
    assert est["vs_allreduce_f32"] == 4.0
