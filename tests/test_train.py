"""Training substrate: loss decreases, grad accumulation, optimizer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.policy import uniform_policy
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.train import optimizer as optim
from repro.train.step import cross_entropy, make_train_step


def test_qat_loss_decreases():
    """A tiny model learns the synthetic arithmetic task under 4-bit QAT."""
    cfg = reduced_config("qwen3-8b")
    model = LM(cfg)
    rt = Runtime(policy=uniform_policy(4, 8, backend="fake_quant"))
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=16, task="arith"))
    ocfg = optim.OptConfig(lr=1e-2, warmup_steps=5, total_steps=80,
                           weight_decay=0.0)
    step = jax.jit(make_train_step(model, rt, ocfg))
    state = {"params": params, "opt": optim.init_state(params, ocfg)}
    losses = []
    for i in range(60):
        b = data.batch(i)
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["ce"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.85, \
        losses[:3] + losses[-3:]


def test_grad_accumulation_matches_full_batch():
    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    rt = Runtime(policy=uniform_policy(8, 8, backend="dense"))
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                  global_batch=8))
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    ocfg = optim.OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    s1 = jax.jit(make_train_step(model, rt, ocfg, accum_steps=1))
    s4 = jax.jit(make_train_step(model, rt, ocfg, accum_steps=4))
    state = {"params": params, "opt": optim.init_state(params, ocfg)}
    out1, m1 = s1(state, batch)
    out4, m4 = s4(state, batch)
    assert float(m1["ce"]) == pytest.approx(float(m4["ce"]), rel=1e-3)
    for a, b in zip(jax.tree.leaves(out1["params"]),
                    jax.tree.leaves(out4["params"])):
        # bf16 param storage: accumulation-order differences can flip the
        # last mantissa bit of a handful of parameters.
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=4e-3)


def test_lr_schedule():
    cfg = optim.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    assert float(optim.lr_at(cfg, jnp.asarray(0))) < 0.2
    assert float(optim.lr_at(cfg, jnp.asarray(10))) == pytest.approx(1.0, abs=0.05)
    assert float(optim.lr_at(cfg, jnp.asarray(100))) == pytest.approx(0.1, abs=0.01)


def test_moment_dtype_bf16():
    params = {"w": jnp.zeros((4, 4), jnp.bfloat16)}
    st = optim.init_state(params, optim.OptConfig(moment_dtype="bfloat16"))
    assert st["m"]["w"].dtype == jnp.bfloat16


def test_grad_clip_bounds_update():
    p = {"w": jnp.ones((2, 2))}
    g = {"w": jnp.full((2, 2), 1e6)}
    cfg = optim.OptConfig(lr=1e-2, grad_clip=1.0, warmup_steps=0,
                          total_steps=10, weight_decay=0.0)
    st = optim.init_state(p, cfg)
    newp, _, metrics = optim.apply_updates(p, g, st, cfg)
    assert float(metrics["grad_norm"]) > 1e5
    assert np.abs(np.asarray(newp["w"]) - 1.0).max() < 0.1


def test_cross_entropy_masking():
    logits = jnp.zeros((1, 4, 8))
    labels = jnp.zeros((1, 4), jnp.int32)
    mask = jnp.asarray([[1.0, 1.0, 0.0, 0.0]])
    full = cross_entropy(logits, labels)
    masked = cross_entropy(logits, labels, mask)
    assert float(full) == pytest.approx(float(masked))  # uniform logits
